"""End-to-end YCSB client/server simulation over the RDMA transport.

The paper's headline numbers (1.45x–2.43x throughput, ~1.7x latency) are
end-to-end: a client runs a YCSB mix against a remote PM server, and the
scheme decides what every op puts on the wire.  This module closes that
loop: the scheme executes (jitted, exact), its `OpResult.plan` is posted
through one `RemoteMemory` endpoint with doorbell batching, and the
analytical `LinkModel` prices the batch — yielding per-scheme throughput
and p50/p99 latency whose RELATIVE ordering is the reproducible claim
(continuity > level > pfarm on read-heavy mixes; absolutes depend on the
calibration constants, all in `LinkModel`).

Reads are priced from the scheme's exact verb plan.  Writes are priced
from a plan SYNTHESIZED from the scheme's own `CostLedger`: one ordered
remote WRITE (+ remote-persist fence, Kashyap et al.) per PM write the op
charges — payload stores as slot-sized WRITEs, the final 8-byte commit
word last.  That reproduces the write-side round-trip asymmetry exactly
where the paper locates it (continuity 2 fenced writes vs P-FaRM-KV's 5
RECIPE-logged writes).
"""

from __future__ import annotations

from typing import Dict, Optional

import jax
import numpy as np

from repro import obs
from repro.core.continuity import SLOT_BYTES
from repro.data import ycsb
from repro.rdma import verbs as rv
from repro.rdma.transport import LinkModel, RemoteMemory

COMMIT_BYTES = 8        # the 8-byte atomic indicator/token commit word

# YCSB mixes the simulation drives (paper §V-A): A/B/C the paper's trio,
# D read-latest (95% read / 5% insert, reads skewed to newest keys),
# E short scans (95% scan / 5% insert — continuity's contiguous-SBucket
# showcase), F read-modify-write (50% read / 50% RMW on the SAME key)
SIM_WORKLOADS = ("A", "B", "C", "D", "E", "F")


def write_plan(B: int, pm_per_op: int, extra_ops: int = 0,
               payload_bytes: int = SLOT_BYTES) -> rv.VerbPlan:
    """Synthesize the remote-write verb plan for B ops: each op issues its
    PM-write count as ordered slot-sized WRITEs ending in the 8-byte
    commit WRITE, every store followed by a remote-persist fence (each
    fenced store is a dependent round — DESIGN.md §8's ordering rule for
    correct remote persistence).

    The last ``extra_ops`` rows charge ``pm_per_op + 1`` writes (the
    scheme's fallback/logged path), the rest ``pm_per_op`` — so a batch
    whose ledger mixes paths keeps its EXACT PM-write total and a
    distinct latency tail, instead of a rounded uniform mean."""
    import jax.numpy as jnp
    pm = max(1, int(pm_per_op))
    extra_ops = min(max(0, int(extra_ops)), B)
    counts = jnp.where(jnp.arange(B) >= B - extra_ops, pm + 1, pm)
    lanes = []
    for d in range(pm + (1 if extra_ops else 0)):
        active = d < counts
        lanes.append((jnp.where(active, rv.WRITE, rv.NOOP), rv.REGION_TABLE,
                      0, jnp.where(d == counts - 1, COMMIT_BYTES,
                                   payload_bytes), d, True))
    return rv.pack(B, lanes)


def post_ledger_writes(mem: RemoteMemory, n_ok: int, total_pm: int):
    """Post the exact-total fenced write plan a batch's `CostLedger`
    implies: ``floor(total_pm / n_ok)`` writes per op with the remainder
    ops charging one more (the scheme's logged/fallback-path tail), so
    Σ per-op counts == the ledger.  The ONE apportioning rule every
    driver (this sim's update/insert paths, the cluster store's replica
    fan-out) shares.  Returns the `Completion`, or None for an empty or
    write-free batch."""
    if not (n_ok and total_pm):
        return None
    lo = max(1, total_pm // n_ok)
    return mem.post(write_plan(n_ok, lo, extra_ops=total_pm - lo * n_ok))


def _mix_counts(workload: str, batch: int):
    """(reads, updates, inserts, scans, rmw) per batch.  An RMW op counts
    toward BOTH reads and updates (it posts a read round then a fenced
    write round on the same key); ``rmw`` is the overlap so callers can
    count logical ops as ``reads + updates + inserts + scans - rmw``."""
    mix = dict(ycsb.WORKLOADS[workload])
    n_rmw = int(batch * mix.get(ycsb.OP_RMW, 0))
    n_read = int(batch * mix.get(ycsb.OP_READ, 0)) + n_rmw
    n_upd = int(batch * mix.get(ycsb.OP_UPDATE, 0)) + n_rmw
    n_ins = int(batch * mix.get(ycsb.OP_INSERT, 0))
    n_scan = int(batch * mix.get(ycsb.OP_SCAN, 0))
    return n_read, n_upd, n_ins, n_scan, n_rmw


def run_ycsb(scheme: str, workload: str, *, num_records: int = 3000,
             num_ops: int = 4000, batch: int = 500,
             load_factor: float = 0.7, link: Optional[LinkModel] = None,
             seed: int = 0) -> Dict[str, float]:
    """One scheme x workload cell: load ``num_records``, run ``num_ops`` of
    the mix in doorbell-batched rounds, return simulated throughput and
    latency percentiles.  Deterministic given the seed (the transport
    model has no noise terms), so CI can band the relative ordering.
    """
    from repro import api
    assert workload in SIM_WORKLOADS, workload
    n_read, n_upd, n_ins, n_scan, n_rmw = _mix_counts(workload, batch)
    n_logical = n_read + n_upd + n_ins + n_scan - n_rmw
    rounds = -(-num_ops // max(1, n_logical))
    slots = int(np.ceil((num_records + n_ins * rounds) / load_factor))
    store = api.make_store(scheme, table_slots=slots,
                           policy=api.ExecPolicy(transport="sim"))
    mem = RemoteMemory.from_policy(store.policy, link)
    assert mem is not None

    rng = np.random.RandomState(seed)
    K = ycsb.make_key(np.arange(num_records))
    V = ycsb.make_value(rng, num_records)
    table, res = store.insert(store.create(), K, V)
    loaded = np.flatnonzero(np.asarray(res.ok))     # read only resident keys
    zipf = ycsb.Zipf(len(loaded))
    # YCSB scrambles zipfian ranks over the keyspace: popularity must be
    # independent of insertion order (rank==id would make the hottest keys
    # the FIRST inserted, i.e. the best-placed, flattering the multi-probe
    # baselines with an empty-table placement no aged store has)
    scramble = rng.permutation(len(loaded))
    order_ids = list(loaded)      # insertion order (D's read-latest axis)
    next_id = num_records

    # per-op-type latency sketches (local per cell; folded into the
    # installed obs registry at the end so a traced run exports them
    # under e2e.op_us{scheme,workload,op})
    h_read, h_write = obs.Histogram(), obs.Histogram()
    ops_done = 0
    while ops_done < num_ops:
        if workload == "D":
            # read-latest: popularity IS recency, so the zipf ranks index
            # the insertion order from the newest end (no scramble)
            zipf_d = ycsb.Zipf(len(order_ids))
            ids = np.asarray(order_ids)[len(order_ids) - 1
                                        - zipf_d.sample(rng, n_read)]
        elif n_read:
            ids = loaded[scramble[zipf.sample(rng, n_read)]]
        if n_read:
            hits = store.lookup(table, ycsb.make_key(ids))
            comp = mem.post(hits.plan, tag="read")
            h_read.record_many(comp.op_us)
        if n_scan:
            # YCSB-E short scans: start key zipf-ranked, span uniform.
            # The scan's wire cost IS the scan plan (the start record
            # rides inside the fetched range — nothing else is posted);
            # the jitted lookup runs for start-key correctness only.
            starts = loaded[scramble[zipf.sample(rng, n_scan)]]
            spans = ycsb.scan_lengths(rng, n_scan)
            skeys = ycsb.make_key(starts)
            store.lookup(table, skeys)
            comp = mem.post(store.scan_plan(table, skeys, spans),
                            tag="scan")
            h_read.record_many(comp.op_us)
        if n_ins:
            ins_ids = np.arange(next_id, next_id + n_ins)
            next_id += n_ins
            table, ires = store.insert(table, ycsb.make_key(ins_ids),
                                       ycsb.make_value(rng, n_ins))
            iok = np.asarray(ires.ok)
            order_ids.extend(int(i) for i in ins_ids[iok])
            comp = post_ledger_writes(mem, int(iok.sum()),
                                      int(ires.ledger.pm_writes))
            if comp is not None:
                h_write.record_many(comp.op_us)
        if n_upd:
            # F's updates are the write half of read-modify-write: they
            # target the keys the SAME round just read (the RMW tail of
            # the read batch), not an independent zipf draw
            ids = (ids[-n_upd:] if n_rmw
                   else loaded[scramble[zipf.sample(rng, n_upd)]])
            table, ures = store.update(table, ycsb.make_key(ids),
                                       ycsb.make_value(rng, n_upd))
            comp = post_ledger_writes(mem, int(np.asarray(ures.ok).sum()),
                                      int(ures.ledger.pm_writes))
            if comp is not None:
                h_write.record_many(comp.op_us)
        ops_done += n_logical
    jax.block_until_ready(table)

    # all percentiles come from the merged sketch — the same buckets the
    # obs export carries, so bench numbers and exports cannot disagree
    merged = obs.Histogram()
    merged.merge(h_read)
    merged.merge(h_write)
    reg = obs.get_registry()
    reg.histogram("e2e.op_us", scheme=scheme, workload=workload,
                  op="read").merge(h_read)
    reg.histogram("e2e.op_us", scheme=scheme, workload=workload,
                  op="write").merge(h_write)
    out = {
        "ops_per_s": ops_done / mem.total_us * 1e6,
        "p50_us": merged.percentile(50),
        "p99_us": merged.percentile(99),
        "doorbells": float(mem.doorbells),
        "verbs_per_op": mem.total_verbs / ops_done,
        "bytes_per_op": mem.total_bytes / ops_done,
    }
    if h_read.count:
        out["read_p50_us"] = h_read.percentile(50)
    if h_write.count:
        out["write_p50_us"] = h_write.percentile(50)
    return out


def run_all(schemes=None, workloads=SIM_WORKLOADS, **kw) -> Dict[str, dict]:
    """{scheme: {workload: cell}} over the registered schemes."""
    from repro import api
    out: Dict[str, dict] = {}
    for s in (schemes or api.available_schemes()):
        for wl in workloads:
            out.setdefault(s, {})[wl] = run_ycsb(s, wl, **kw)
    return out

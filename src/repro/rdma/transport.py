"""`RemoteMemory`: the simulated one-sided transport endpoint.

Executes `VerbPlan`s with doorbell batching against an analytical latency
model and accumulates wire counters — the substrate the YCSB end-to-end
simulation, the serving scheduler's per-step flush, and the benchmarks
drive.  There is no real NIC here: correctness results come from the
schemes' own jitted lookups; the transport prices WHAT the scheme put on
the wire (the verb plan), which is exactly the quantity the paper's
throughput/latency comparison is about.

Doorbell batching: all verbs of one ``post()`` that share a dependency
depth coalesce into ONE doorbell ring = one round trip; depth k+1 issues
only after depth k completes (chained reads, ordered persist sequences).
A batch of B independent lookups therefore costs ONE RTT regardless of B —
per-op cost is dominated by per-verb NIC processing and payload movement,
which is what separates the schemes.

`LinkModel` holds every calibrated constant in one place (DESIGN.md §8
documents the calibration): RTT, NIC line rate, PM media bandwidth
(asymmetric read/write — Optane), per-WQE processing, and the
remote-persist fence cost (the read-after-WRITE flush of Kashyap et al.,
"Correct, Fast Remote Persistence").
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional

import numpy as np

from repro.rdma import verbs as rv


@dataclasses.dataclass(frozen=True)
class LinkModel:
    """Analytical RDMA + PM cost constants (microseconds / bytes-per-us).

    Defaults are calibrated to the paper's testbed class (ConnectX-class
    RNIC + Optane DCPMM): ~2 us one-sided RTT, 12 GB/s NIC line rate,
    asymmetric PM media bandwidth, sub-us WQE processing, and a
    remote-persist fence priced as a small dependent flush."""

    rtt_us: float = 2.0              # doorbell ring -> completion, one round
    nic_bytes_per_us: float = 12_000.0   # NIC line rate (12 GB/s)
    pm_read_bytes_per_us: float = 2_500.0    # PM media random read (2.5 GB/s
    #                                          — DCPMM 256 B access granule)
    pm_write_bytes_per_us: float = 2_000.0   # PM media write (2 GB/s)
    verb_us: float = 0.4             # per-WQE NIC/doorbell processing
    fence_us: float = 0.5            # remote-persist flush (RAW read)

    def verb_cost_us(self, verb: np.ndarray, nbytes: np.ndarray,
                     fence: np.ndarray) -> np.ndarray:
        """Element-wise service cost of each verb (RTT excluded — that is
        per round, not per verb)."""
        nbytes = nbytes.astype(np.float64)
        is_read = verb == rv.READ
        is_write = (verb == rv.WRITE) | (verb == rv.CAS)
        active = verb != rv.NOOP
        media = np.where(is_read, nbytes / self.pm_read_bytes_per_us,
                         np.where(is_write,
                                  nbytes / self.pm_write_bytes_per_us, 0.0))
        wire = np.where(active, nbytes / self.nic_bytes_per_us, 0.0)
        return (active * self.verb_us + wire + media
                + (fence & is_write) * self.fence_us)


class Completion(NamedTuple):
    """Result of one ``post()`` (one client batch through the transport).

    ``batch_us``   simulated wall time of the whole doorbell-batched post;
    ``op_us``      (B,) unloaded per-op latency (the op alone on the wire:
                   one RTT per dependent round plus its own verb costs —
                   the paper's latency-figure quantity);
    ``rounds``     dependent round trips (doorbells rung);
    ``verbs``      active verbs posted;
    ``bytes``      wire payload moved.
    """

    batch_us: float
    op_us: np.ndarray
    rounds: int
    verbs: int
    bytes: int


class RemoteMemory:
    """One simulated RNIC endpoint + remote PM region set.

    Host-side and stateful (aggregate counters) — drive it OUTSIDE jit with
    the plans jitted code returns (`OpResult.plan` is a pure pytree).
    """

    def __init__(self, link: Optional[LinkModel] = None):
        self.link = link or LinkModel()
        self.total_us = 0.0
        self.doorbells = 0
        self.posts = 0
        self.total_verbs = 0
        self.total_bytes = 0

    @classmethod
    def from_policy(cls, policy,
                    link: Optional[LinkModel] = None) -> Optional["RemoteMemory"]:
        """Transport selection threaded through `api.ExecPolicy`: returns an
        endpoint for ``transport="sim"``, None for ``transport="none"``."""
        if getattr(policy, "transport", "none") == "none":
            return None
        return cls(link)

    def post(self, plan: rv.VerbPlan) -> Completion:
        """Execute one doorbell-batched verb plan; returns its `Completion`
        and folds it into the endpoint's aggregate counters."""
        verb = np.asarray(plan.verb)
        nbytes = np.asarray(plan.nbytes)
        depth = np.asarray(plan.depth)
        fence = np.asarray(plan.fence)
        active = verb != rv.NOOP
        cost = self.link.verb_cost_us(verb, nbytes, fence)    # (B, M)

        rounds = int((depth + 1)[active].max()) if active.any() else 0
        batch_us = 0.0
        for d in range(rounds):
            sel = active & (depth == d)
            if sel.any():
                batch_us += self.link.rtt_us + float(cost[sel].sum())

        # unloaded per-op latency: each op pays one RTT per round it
        # participates in, plus its own verb service costs
        op_rounds = np.where(active, depth + 1, 0).max(axis=1)
        op_us = op_rounds * self.link.rtt_us + (cost * active).sum(axis=1)

        nverbs = int(active.sum())
        nb = int(nbytes[active].sum())
        self.total_us += batch_us
        self.doorbells += rounds
        self.posts += 1
        self.total_verbs += nverbs
        self.total_bytes += nb
        return Completion(batch_us, op_us, rounds, nverbs, nb)

    def stats(self) -> dict:
        return {
            "posts": self.posts,
            "doorbells": self.doorbells,
            "verbs": self.total_verbs,
            "bytes": self.total_bytes,
            "simulated_us": self.total_us,
        }

"""`RemoteMemory`: the simulated one-sided transport endpoint.

Executes `VerbPlan`s with doorbell batching against an analytical latency
model and accumulates wire counters — the substrate the YCSB end-to-end
simulation, the serving scheduler's per-step flush, and the benchmarks
drive.  There is no real NIC here: correctness results come from the
schemes' own jitted lookups; the transport prices WHAT the scheme put on
the wire (the verb plan), which is exactly the quantity the paper's
throughput/latency comparison is about.

Doorbell batching: all verbs of one ``post()`` that share a dependency
depth coalesce into ONE doorbell ring = one round trip; depth k+1 issues
only after depth k completes (chained reads, ordered persist sequences).
A batch of B independent lookups therefore costs ONE RTT regardless of B —
per-op cost is dominated by per-verb NIC processing and payload movement,
which is what separates the schemes.

`LinkModel` holds every calibrated constant in one place (DESIGN.md §8
documents the calibration): RTT, NIC line rate, PM media bandwidth
(asymmetric read/write — Optane), per-WQE processing, and the
remote-persist fence cost (the read-after-WRITE flush of Kashyap et al.,
"Correct, Fast Remote Persistence").
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional

import numpy as np

from repro.obs import trace as obs
from repro.obs.metrics import MetricsRegistry
from repro.rdma import verbs as rv


class DeliveryTimeout(RuntimeError):
    """A verb round exhausted its retry budget (every attempt dropped)."""


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Per-round timeout + capped exponential backoff with jitter.

    A doorbell round whose completion does not arrive within
    ``timeout_us`` is retried after ``backoff_us(attempt)`` of waiting:
    ``base_us * 2**attempt`` capped at ``cap_us``, with a ``jitter``
    fraction of the delay randomized (decorrelates retry storms across
    clients — the rng is injected so runs stay seeded).  Retrying a
    FENCED WRITE round is idempotent by construction: payload stores are
    blind writes and the round's commit is ONE atomic 8-byte indicator
    store, so a replayed prefix can never be observed half-applied
    (tests/test_chaos.py proves this per scheme over every prefix).
    After ``max_attempts`` total attempts the round raises
    `DeliveryTimeout` — the caller's failure-suspicion signal.
    """

    timeout_us: float = 50.0
    max_attempts: int = 8
    base_us: float = 4.0
    cap_us: float = 200.0
    jitter: float = 0.5

    def backoff_us(self, attempt: int,
                   rng: Optional[np.random.RandomState] = None) -> float:
        d = min(self.base_us * (2.0 ** attempt), self.cap_us)
        if rng is None or self.jitter <= 0.0:
            return d
        return d * (1.0 - self.jitter + self.jitter * rng.random_sample())


@dataclasses.dataclass
class FaultInjector:
    """Seeded delivery faults for one endpoint (the chaos engine's knob).

    Each doorbell round independently draws one outcome: ``drop`` (the
    round vanishes — the client times out and retries), ``dup`` (the NIC
    delivers the round twice — harmless for reads and for fenced write
    rounds, which are idempotent, but the duplicate's verbs/bytes are
    paid), ``reorder`` (verbs within the round arrive out of post order —
    legal inside one doorbell, no intra-round ordering is guaranteed, but
    the completion is skewed by one extra RTT), or clean delivery.
    Deterministic given the seed and call order.
    """

    drop_p: float = 0.0
    dup_p: float = 0.0
    reorder_p: float = 0.0
    seed: int = 0

    def __post_init__(self):
        self.rng = np.random.RandomState(self.seed)
        self.injected = {"drop": 0, "dup": 0, "reorder": 0}

    def draw(self) -> str:
        u = self.rng.random_sample()
        for kind, p in (("drop", self.drop_p), ("dup", self.dup_p),
                        ("reorder", self.reorder_p)):
            if u < p:
                self.injected[kind] += 1
                return kind
            u -= p
        return "ok"


@dataclasses.dataclass(frozen=True)
class LinkModel:
    """Analytical RDMA + PM cost constants (microseconds / bytes-per-us).

    Defaults are calibrated to the paper's testbed class (ConnectX-class
    RNIC + Optane DCPMM): ~2 us one-sided RTT, 12 GB/s NIC line rate,
    asymmetric PM media bandwidth, sub-us WQE processing, and a
    remote-persist fence priced as a small dependent flush."""

    rtt_us: float = 2.0              # doorbell ring -> completion, one round
    nic_bytes_per_us: float = 12_000.0   # NIC line rate (12 GB/s)
    pm_read_bytes_per_us: float = 2_500.0    # PM media random read (2.5 GB/s
    #                                          — DCPMM 256 B access granule)
    pm_write_bytes_per_us: float = 2_000.0   # PM media write (2 GB/s)
    verb_us: float = 0.4             # per-WQE NIC/doorbell processing
    fence_us: float = 0.5            # remote-persist flush (RAW read)

    def verb_cost_us(self, verb: np.ndarray, nbytes: np.ndarray,
                     fence: np.ndarray) -> np.ndarray:
        """Element-wise service cost of each verb (RTT excluded — that is
        per round, not per verb)."""
        nbytes = nbytes.astype(np.float64)
        is_read = verb == rv.READ
        is_write = (verb == rv.WRITE) | (verb == rv.CAS)
        active = verb != rv.NOOP
        media = np.where(is_read, nbytes / self.pm_read_bytes_per_us,
                         np.where(is_write,
                                  nbytes / self.pm_write_bytes_per_us, 0.0))
        wire = np.where(active, nbytes / self.nic_bytes_per_us, 0.0)
        return (active * self.verb_us + wire + media
                + (fence & is_write) * self.fence_us)

    def cohort_move_us(self, read_bytes: float, write_bytes: float,
                       verbs: int = 4, fences: int = 3) -> float:
        """Stall cost of relocating ONE resize cohort (one bucket-pair row):
        read the source row, write its items + the new indicator words, CAS
        the cutover token — one dependent round on the wire plus the verb /
        media / fence service times.  This is the unit the ``resize_step``
        SLO controller divides a per-step stall budget by (see
        ``api.stores.ContinuityStore.begin_resize(step_slo_us=...)``)."""
        wire = (read_bytes + write_bytes) / self.nic_bytes_per_us
        media = (read_bytes / self.pm_read_bytes_per_us
                 + write_bytes / self.pm_write_bytes_per_us)
        return (self.rtt_us + verbs * self.verb_us + wire + media
                + fences * self.fence_us)


class Completion(NamedTuple):
    """Result of one ``post()`` (one client batch through the transport).

    ``batch_us``   simulated wall time of the whole doorbell-batched post;
    ``op_us``      (B,) unloaded per-op latency (the op alone on the wire:
                   one RTT per dependent round plus its own verb costs —
                   the paper's latency-figure quantity);
    ``rounds``     dependent round trips (doorbells rung);
    ``verbs``      active verbs posted;
    ``bytes``      wire payload moved.
    """

    batch_us: float
    op_us: np.ndarray
    rounds: int
    verbs: int
    bytes: int


class RemoteMemory:
    """One simulated RNIC endpoint + remote PM region set.

    Host-side and stateful (aggregate counters) — drive it OUTSIDE jit with
    the plans jitted code returns (`OpResult.plan` is a pure pytree).
    """

    def __init__(self, link: Optional[LinkModel] = None,
                 faults: Optional[FaultInjector] = None,
                 retry: Optional[RetryPolicy] = None,
                 registry: Optional[MetricsRegistry] = None):
        self.link = link or LinkModel()
        self.faults = faults
        # faults without a retry policy would silently lose rounds; the
        # default policy makes every drop a timeout + backoff + replay
        self.retry = retry or (RetryPolicy() if faults is not None else None)
        # every wire counter lives in the endpoint's registry; the legacy
        # attribute API (``mem.doorbells`` etc.) survives as properties
        # reading it, and ``stats()`` is a view over the same sinks
        self.metrics = registry if registry is not None else MetricsRegistry()
        # callers label posts ("lookup", "validate", "fill", ...) so the
        # cache benchmarks can separate validation traffic from miss
        # traffic on ONE endpoint; first-seen order keeps by_tag stable
        self._tags: list = []

    def _count(self, name: str, n: float = 1,
               tag: Optional[str] = None) -> None:
        self.metrics.counter(name).inc(n)
        if tag is not None:
            self.metrics.counter(name, tag=tag).inc(n)

    # ---- legacy counter attributes, now registry views -------------------
    @property
    def total_us(self) -> float:
        return self.metrics.value("rdma.simulated_us")

    @property
    def posts(self) -> int:
        return int(self.metrics.value("rdma.posts"))

    @property
    def doorbells(self) -> int:
        return int(self.metrics.value("rdma.doorbells"))

    @property
    def total_verbs(self) -> int:
        return int(self.metrics.value("rdma.verbs"))

    @property
    def total_bytes(self) -> int:
        return int(self.metrics.value("rdma.bytes"))

    @property
    def retries(self) -> int:
        """Rounds replayed after a timeout."""
        return int(self.metrics.value("rdma.retries"))

    @property
    def timeouts(self) -> int:
        """Dropped deliveries waited out."""
        return int(self.metrics.value("rdma.timeouts"))

    @property
    def duplicates(self) -> int:
        """Rounds the NIC delivered twice."""
        return int(self.metrics.value("rdma.duplicates"))

    @property
    def reorders(self) -> int:
        """Intra-round reordered deliveries."""
        return int(self.metrics.value("rdma.reorders"))

    @property
    def backoff_us(self) -> float:
        """Total backoff waited before replays."""
        return self.metrics.value("rdma.backoff_us")

    @property
    def give_ups(self) -> int:
        """Rounds that exhausted max_attempts."""
        return int(self.metrics.value("rdma.give_ups"))

    @property
    def by_tag(self) -> dict:
        """Per-tag wire counters incl. per-tag retries/timeouts (so cache
        validate retries are attributable apart from write retries)."""
        v = self.metrics.value
        out = {}
        for t in self._tags:
            out[t] = {
                "posts": int(v("rdma.posts", tag=t)),
                "doorbells": int(v("rdma.doorbells", tag=t)),
                "verbs": int(v("rdma.verbs", tag=t)),
                "bytes": int(v("rdma.bytes", tag=t)),
                "simulated_us": v("rdma.simulated_us", tag=t),
                "retries": int(v("rdma.retries", tag=t)),
                "timeouts": int(v("rdma.timeouts", tag=t)),
            }
        return out

    @classmethod
    def from_policy(cls, policy, link: Optional[LinkModel] = None,
                    faults: Optional[FaultInjector] = None,
                    retry: Optional[RetryPolicy] = None
                    ) -> Optional["RemoteMemory"]:
        """Transport selection threaded through `api.ExecPolicy`: returns an
        endpoint for ``transport="sim"``, None for ``transport="none"``."""
        if getattr(policy, "transport", "none") == "none":
            return None
        return cls(link, faults=faults, retry=retry)

    def _deliver_round(self, round_cost_us: float,
                       tag: Optional[str] = None) -> float:
        """One doorbell round through the fault/retry loop: returns the
        simulated time the round took (clean = RTT + service; each drop
        adds a timeout + backoff; a duplicate pays the service twice; a
        reorder skews completion by one RTT).  Raises `DeliveryTimeout`
        when ``retry.max_attempts`` deliveries all dropped.  ``tag``
        attributes retry/timeout counts to the post's traffic class."""
        clean = self.link.rtt_us + round_cost_us
        if self.faults is None:
            return clean
        assert self.retry is not None
        spent = 0.0
        for attempt in range(self.retry.max_attempts):
            outcome = self.faults.draw()
            if outcome == "drop":
                self._count("rdma.timeouts", tag=tag)
                self._count("rdma.retries", tag=tag)
                back = self.retry.backoff_us(attempt, self.faults.rng)
                self._count("rdma.backoff_us", back)
                obs.event("rdma.retry", attempt=attempt, tag=tag or "",
                          backoff_us=round(back, 3))
                spent += self.retry.timeout_us + back
                continue
            if outcome == "dup":
                self._count("rdma.duplicates")
                return spent + clean + round_cost_us   # second copy drains too
            if outcome == "reorder":
                self._count("rdma.reorders")
                return spent + clean + self.link.rtt_us
            return spent + clean
        self._count("rdma.give_ups", tag=tag)
        obs.event("rdma.give_up", tag=tag or "",
                  attempts=self.retry.max_attempts)
        raise DeliveryTimeout(
            f"round dropped {self.retry.max_attempts} times "
            f"(waited {spent:.1f}us)")

    def post(self, plan: rv.VerbPlan, tag: Optional[str] = None) -> Completion:
        """Execute one doorbell-batched verb plan; returns its `Completion`
        and folds it into the endpoint's aggregate counters.  With a
        `FaultInjector` attached, every dependent round runs the
        timeout/backoff/replay loop — a `DeliveryTimeout` propagates to
        the caller with the endpoint's clock already advanced (the wait
        happened on the wire whether or not the round landed).  ``tag``
        additionally buckets the post's wire counters under
        ``stats()["by_tag"][tag]``."""
        verb = np.asarray(plan.verb)
        nbytes = np.asarray(plan.nbytes)
        depth = np.asarray(plan.depth)
        fence = np.asarray(plan.fence)
        active = verb != rv.NOOP
        cost = self.link.verb_cost_us(verb, nbytes, fence)    # (B, M)

        rounds = int((depth + 1)[active].max()) if active.any() else 0
        traced = obs.get_tracer() is not None
        is_write = (verb == rv.WRITE) | (verb == rv.CAS)
        batch_us = 0.0
        try:
            for d in range(rounds):
                sel = active & (depth == d)
                if sel.any():
                    batch_us += self._deliver_round(float(cost[sel].sum()),
                                                    tag=tag)
                    if traced:
                        obs.event("rdma.doorbell", round=d,
                                  verbs=int(sel.sum()), tag=tag or "")
                        nf = int((fence & is_write & sel).sum())
                        if nf:
                            obs.event("rdma.fence_wait", n=nf, round=d,
                                      tag=tag or "")
        except DeliveryTimeout:
            self._count("rdma.simulated_us", batch_us, tag=tag)
            self._count("rdma.posts", tag=tag)
            self._note_tag(tag)
            raise

        # unloaded per-op latency: each op pays one RTT per round it
        # participates in, plus its own verb service costs
        op_rounds = np.where(active, depth + 1, 0).max(axis=1)
        op_us = op_rounds * self.link.rtt_us + (cost * active).sum(axis=1)

        nverbs = int(active.sum())
        nb = int(nbytes[active].sum())
        self._count("rdma.simulated_us", batch_us, tag=tag)
        self._count("rdma.posts", tag=tag)
        self._count("rdma.doorbells", rounds, tag=tag)
        self._count("rdma.verbs", nverbs, tag=tag)
        self._count("rdma.bytes", nb, tag=tag)
        self._note_tag(tag)
        # flush-boundary histogram feed: one record_many per post, never
        # per verb (DESIGN.md §13) — per-tag latency tails come for free
        lbl = {"tag": tag} if tag is not None else {}
        self.metrics.histogram("rdma.op_us", **lbl).record_many(op_us)
        self.metrics.histogram("rdma.post_us", **lbl).record(batch_us)
        self.metrics.histogram("rdma.rounds_per_post", **lbl).record(rounds)
        return Completion(batch_us, op_us, rounds, nverbs, nb)

    def _note_tag(self, tag: Optional[str]) -> None:
        if tag is not None and tag not in self._tags:
            self._tags.append(tag)

    def stats(self) -> dict:
        """A view over the endpoint registry — shape unchanged from the
        pre-registry counters (callers index it blindly)."""
        out = {
            "posts": self.posts,
            "doorbells": self.doorbells,
            "verbs": self.total_verbs,
            "bytes": self.total_bytes,
            "simulated_us": self.total_us,
        }
        # the retry counters outlive the injector: an audit phase that
        # quiesces fault injection still reports what the run survived
        if self.faults is not None or self.retries or self.duplicates \
                or self.reorders or self.give_ups:
            out["retries"] = self.retries
            out["timeouts"] = self.timeouts
            out["duplicates"] = self.duplicates
            out["reorders"] = self.reorders
            out["backoff_us"] = self.backoff_us
            out["give_ups"] = self.give_ups
            if self.faults is not None:
                out["injected"] = dict(self.faults.injected)
        by_tag = self.by_tag
        if by_tag:
            out["by_tag"] = by_tag
        return out

"""Chaos engineering for the cluster: seeded fault scenarios + the matrix.

The subsystem turns the failure machinery grown across the repo — crash
storms (`cluster.store.kill` + `FailoverController`), network partitions
with epoch fencing (`partition`/`heal`/`resync`/`stale_write`), delivery
faults with retry/timeout/backoff (`rdma.transport.FaultInjector` +
`RetryPolicy`), quorum-loss read-only degradation — into a SEEDED
scenario grid whose every cell is audited by the zero-committed-loss
re-read and the fencing-completeness count
(``stale_acks_detected == stale_acks_injected``).

    python -m repro.chaos.matrix --smoke --seed 0

runs the CI grid; `scenarios.run_scenario` runs one named cell.
"""

from repro.chaos.scenarios import (SCENARIOS, run_scenario)  # noqa: F401

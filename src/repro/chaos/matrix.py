"""The seeded chaos matrix: every scenario family x YCSB workloads.

    python -m repro.chaos.matrix --smoke --seed 0 [--json OUT.json]

runs the 14-cell grid below (storms incl. mid-join/mid-migration,
partitions with fencing, replica-lag reads, delivery faults, quorum-loss
and retry-exhaustion drills, churn soak — across YCSB A/B/C/E/F) and
gates the run on the aggregate invariants:

  * every cell's own checks hold (zero committed loss everywhere);
  * fencing completeness: EVERY injected stale ack was detected;
  * every transport retry path fired at least once somewhere in the
    grid — drop->timeout->backoff->replay, duplicate absorption,
    reorder re-sync, AND budget exhaustion (give-up -> un-acked round);
  * both degradation paths were observed (read-only write rejection,
    replica-lag read redirect).

Each cell's seed derives from the ONE --seed (seed*1000 + cell index),
and the JSON artifact echoes every cell's coordinates, so any failure
replays bit-exactly with `scenarios.run_scenario`.

Exit status 0 iff every gate holds — the `cluster-chaos` CI job's gate,
schema-checked by `benchmarks/validate_bench.py`.
"""

from __future__ import annotations

import argparse
import json
import sys
from typing import Dict, List, Tuple

from repro.chaos.scenarios import run_scenario

# the grid: (scenario, workload).  Workloads cover the read-heavy trio,
# E (short scans) and F (read-modify-write); every scenario family
# appears at least once.
GRID: Tuple[Tuple[str, str], ...] = (
    ("storm", "A"),
    ("storm", "E"),
    ("storm_mid_join", "B"),
    ("storm_mid_migration", "F"),
    ("partition_fence", "A"),
    ("partition_fence", "E"),
    ("partition_failover", "B"),
    ("lag_reads", "C"),
    ("delivery_faults", "A"),
    ("delivery_faults", "F"),
    ("read_only_degrade", "A"),
    ("timeout_giveup", "A"),
    ("soak", "B"),
    ("soak", "F"),
)


def run_matrix(seed: int = 0, scheme: str = "continuity",
               profile: str = "smoke", verbose: bool = True) -> Dict:
    """Run the full grid; returns the artifact payload (cells + totals +
    gates + ok)."""
    cells: List[dict] = []
    for i, (scenario, workload) in enumerate(GRID):
        cell = run_scenario(scenario, scheme=scheme, workload=workload,
                            seed=seed * 1000 + i, profile=profile)
        cells.append(cell)
        if verbose:
            bad = [k for k, v in cell["checks"].items() if not v]
            print(f"  [{i + 1:2d}/{len(GRID)}] {scenario:22s} x {workload}"
                  f"  seed={cell['seed']:<6d} "
                  f"{'ok' if cell['ok'] else 'FAIL ' + ','.join(bad)}")

    totals = {
        "committed_lost": sum(c["committed_lost"] for c in cells),
        "stale_acks_injected": sum(c["chaos"].get("stale_acks_injected", 0)
                                   for c in cells),
        "stale_acks_detected": sum(c["chaos"].get("stale_acks_detected", 0)
                                   for c in cells),
        "writes_rejected_read_only":
            sum(c["chaos"].get("writes_rejected_read_only", 0)
                for c in cells),
        "lag_read_redirects": sum(c["chaos"].get("lag_read_redirects", 0)
                                  for c in cells),
        "write_timeouts": sum(c["chaos"].get("write_timeouts", 0)
                              for c in cells),
        "retries": sum(c["wire"]["retries"] for c in cells),
        "duplicates": sum(c["wire"]["duplicates"] for c in cells),
        "reorders": sum(c["wire"]["reorders"] for c in cells),
        "backoff_us": sum(c["wire"]["backoff_us"] for c in cells),
        "give_ups": sum(c["wire"]["give_ups"] for c in cells),
    }
    gates = {
        "all_cells_ok": all(c["ok"] for c in cells),
        "zero_committed_loss": totals["committed_lost"] == 0,
        "stale_acks_all_detected":
            (totals["stale_acks_injected"] > 0
             and totals["stale_acks_detected"]
             == totals["stale_acks_injected"]),
        "retry_path_drop": totals["retries"] > 0,
        "retry_path_backoff": totals["backoff_us"] > 0,
        "retry_path_duplicate": totals["duplicates"] > 0,
        "retry_path_reorder": totals["reorders"] > 0,
        "retry_path_give_up": totals["give_ups"] > 0,
        "degradation_read_only": totals["writes_rejected_read_only"] > 0,
        "degradation_lag_redirect": totals["lag_read_redirects"] > 0,
    }
    return {
        "seed": seed, "scheme": scheme, "profile": profile,
        "grid_cells": len(cells), "cells": cells, "totals": totals,
        "gates": gates, "ok": all(gates.values()),
    }


def main(argv=None) -> int:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--seed", type=int, default=0,
                   help="grid seed; cell i runs at seed*1000+i")
    p.add_argument("--scheme", default="continuity")
    p.add_argument("--smoke", action="store_true",
                   help="CI sizes (the default profile is also smoke; "
                        "--full runs the larger grid)")
    p.add_argument("--full", action="store_true")
    p.add_argument("--json", default=None, help="write the artifact here")
    p.add_argument("--trace", default=None, metavar="BASE",
                   help="trace the grid under a deterministic TickClock "
                        "and write BASE.trace.json + BASE.metrics.json "
                        "(the EXPERIMENTS.md top-spans table; NOT gated "
                        "by `repro.obs.report --check` — the blocking-"
                        "resize baselines legitimately burn the "
                        "maintenance SLO)")
    args = p.parse_args(argv)

    profile = "full" if args.full else "smoke"
    print(f"chaos matrix: {len(GRID)} cells, scheme={args.scheme}, "
          f"seed={args.seed}, profile={profile}")
    if args.trace:
        from repro import obs
        with obs.scope(obs.Tracer(obs.TickClock())) as (tracer, reg):
            payload = run_matrix(seed=args.seed, scheme=args.scheme,
                                 profile=profile)
            tpath, mpath = obs.write_export(
                args.trace, tracer, reg,
                meta={"scheme": args.scheme, "seed": args.seed,
                      "profile": profile, "grid_cells": len(GRID)})
        payload["obs_export"] = {"trace": tpath, "metrics": mpath}
        print(f"obs export: {tpath} + {mpath}")
    else:
        payload = run_matrix(seed=args.seed, scheme=args.scheme,
                             profile=profile)
    if args.json:
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=2, sort_keys=True, default=str)

    t = payload["totals"]
    print(f"totals: lost={t['committed_lost']} "
          f"stale={t['stale_acks_detected']}/{t['stale_acks_injected']} "
          f"retries={t['retries']:.0f} dups={t['duplicates']:.0f} "
          f"reorders={t['reorders']:.0f} give_ups={t['give_ups']:.0f} "
          f"rejected={t['writes_rejected_read_only']} "
          f"lag_redirects={t['lag_read_redirects']}")
    for gate, okv in payload["gates"].items():
        if not okv:
            print(f"FAIL gate: {gate}", file=sys.stderr)
    print("chaos matrix:", "PASS" if payload["ok"] else "FAIL")
    return 0 if payload["ok"] else 1


if __name__ == "__main__":
    sys.exit(main())

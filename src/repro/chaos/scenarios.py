"""Named chaos scenarios: each composes faults into one audited cell.

A scenario is a recipe ``(scheme, workload, seed, sizes) -> cell dict``.
Most drive `cluster.sim.run_cluster` with an event schedule derived from
the run length (storms, partitions, churn); two are direct drills on a
`ClusterStore` for paths a YCSB run cannot force deterministically
(quorum-loss read-only, retry-budget exhaustion).  Every cell carries
the same shape:

    scenario / scheme / workload / seed    the cell's coordinates
    checks      {name: bool} — the invariants THIS scenario asserts
    ok          all(checks.values())
    committed_lost, chaos, wire            the audit + counter payload

The ONE seed in the cell is the only entropy: the YCSB streams, the
event payloads, and the delivery-fault draws all derive from it, so any
failing cell replays bit-exactly from its coordinates.

Invariants by scenario family:

  * storms (correlated kills, mid-join, mid-migration): zero committed
    loss, every kill detected and promoted, rebalance bound holds;
  * partitions: zero committed loss AND fencing completeness — every
    stale ack the partitioned ex-primary took is detected at
    resync/failover and none is visible afterwards;
  * delivery faults: zero committed loss with drops retried (capped
    exponential backoff), duplicates absorbed, reorders re-synced;
  * degradation drills: quorum loss rejects writes (never acks it could
    lose) while reads keep serving; an exhausted retry budget surfaces
    as an UN-acked round, not a lost one.
"""

from __future__ import annotations

from typing import Callable, Dict

import numpy as np

from repro.cluster.sim import run_cluster
from repro.cluster.store import ClusterStore
from repro.data import ycsb
from repro.rdma.transport import FaultInjector, RetryPolicy

# one grid-wide knob set per profile: identical node_slots/batch across
# cells keeps the jitted scheme ops compiling ONCE per scheme
SIZES = {
    "smoke": dict(num_records=400, num_ops=800, batch=200, node_slots=2048),
    "full": dict(num_records=1000, num_ops=2000, batch=250, node_slots=4096),
}

_WIRE_KEYS = ("retries", "timeouts", "duplicates", "reorders",
              "backoff_us", "give_ups")


def _mild_faults(seed: int) -> FaultInjector:
    """The grid's background weather: drop/dup/reorder rates high enough
    to exercise every retry path in a few thousand rounds, low enough
    that the retry budget (8 attempts) never exhausts by chance
    (P(give-up) = drop_p^8 ~ 2.6e-6 per round at 0.2)."""
    return FaultInjector(drop_p=0.10, dup_p=0.05, reorder_p=0.05, seed=seed)


def _wire_totals(stats: dict) -> Dict[str, float]:
    tot = {k: 0.0 for k in _WIRE_KEYS}
    for st in stats.get("nodes", {}).values():
        for k in _WIRE_KEYS:
            tot[k] += st.get("wire", {}).get(k, 0)
    return tot


def _cell(scenario: str, scheme: str, workload: str, seed: int,
          checks: Dict[str, bool], payload: dict) -> dict:
    return {
        "scenario": scenario, "scheme": scheme, "workload": workload,
        "seed": seed, "checks": checks, "ok": all(checks.values()),
        "committed_lost": payload.get("committed_lost", 0),
        "chaos": payload.get("chaos", {}),
        "wire": _wire_totals(payload.get("stats", {})),
        "events": [e.get("event", "?") for e in payload.get("events", [])],
        "ops_per_s": payload.get("ops_per_s", 0.0),
    }


def _fencing_checks(c: dict) -> Dict[str, bool]:
    ch = c["chaos"]
    return {
        "zero_committed_loss": c["committed_lost"] == 0,
        "stale_acks_all_detected":
            ch["stale_acks_detected"] == ch["stale_acks_injected"],
        "stale_acks_present": ch["stale_acks_injected"] > 0,
    }


# -- storm family -----------------------------------------------------------
def storm(scheme: str, workload: str, seed: int, sizes: dict) -> dict:
    """Correlated multi-node crash storm: two nodes of a 6-node R=3
    cluster die in the SAME round (<= R-1, so every key keeps a copy),
    a third dies later; heartbeats detect, replicas promote, R is
    restored — and every acked op survives."""
    quarter, three_q = sizes["num_ops"] // 4, 3 * sizes["num_ops"] // 4
    # tight detection + wide spacing: the storm's later kill must land
    # AFTER the first two promotions re-replicated (detection takes two
    # silent rounds), or three failures would overlap — beyond the
    # <= R-1 SIMULTANEOUS-failure contract — and acks taken in the
    # window would genuinely lose their last copy
    c = run_cluster(scheme, workload, nodes=6, replicas=3,
                    events=[("kill", quarter, "pm1"),
                            ("kill", quarter, "pm4"),
                            ("kill", three_q, "pm2")],
                    seed=seed, heartbeat_timeout=1.0,
                    faults=_mild_faults(seed),
                    retry=RetryPolicy(), **sizes)
    return _cell("storm", scheme, workload, seed, {
        "zero_committed_loss": c["committed_lost"] == 0,
        "all_kills_promoted":
            sum(1 for e in c["events"] if e["event"] == "failover") == 3,
        "log_free_recovery": all(e.get("recovery_log_free", True)
                                 for e in c["events"]),
    }, c)


def storm_mid_join(scheme: str, workload: str, seed: int,
                   sizes: dict) -> dict:
    """A primary dies INSIDE a join's dual-read window: the pending
    cutover must re-target the post-failover membership instead of
    resurrecting the dead node."""
    t = sizes["num_ops"] // 3
    c = run_cluster(scheme, workload, nodes=4, replicas=2,
                    events=[("join", t, "pmJ"), ("kill", t, "pm0")],
                    seed=seed, faults=_mild_faults(seed),
                    retry=RetryPolicy(), **sizes)
    return _cell("storm_mid_join", scheme, workload, seed, {
        "zero_committed_loss": c["committed_lost"] == 0,
        "kill_promoted": any(e["event"] == "failover" for e in c["events"]),
        "rebalance_within_bound": c["rebalance_within_bound"],
    }, c)


def storm_mid_migration(scheme: str, workload: str, seed: int,
                        sizes: dict) -> dict:
    """The JOINER dies inside its own migration window: it owned nothing
    yet, so the join is void — the source stays authoritative and no
    key may be lost or double-homed."""
    t = sizes["num_ops"] // 3
    c = run_cluster(scheme, workload, nodes=4, replicas=2,
                    events=[("join", t, "pmJ"), ("kill", t, "pmJ")],
                    seed=seed, **sizes)
    return _cell("storm_mid_migration", scheme, workload, seed, {
        "zero_committed_loss": c["committed_lost"] == 0,
        "join_voided": c["nodes_final"] == 4,
        "joiner_death_detected":
            any(e["event"] == "failover" and e["dead"] == "pmJ"
                for e in c["events"]),
    }, c)


# -- partition family -------------------------------------------------------
def partition_fence(scheme: str, workload: str, seed: int,
                    sizes: dict) -> dict:
    """Partition -> stale unfenced acks -> heal inside the suspicion
    grace window -> resync.  The grace window keeps the monitor from
    promoting (the node is partitioned, NOT dead); the epoch fence
    detects every stale ack at resync and none survives."""
    q = sizes["num_ops"] // 4
    c = run_cluster(scheme, workload, nodes=4, replicas=2,
                    events=[("partition", q, "pm1"), ("stale", q + 1, "pm1"),
                            ("heal", 2 * q, "pm1"), ("resync", 3 * q, "pm1")],
                    seed=seed, heartbeat_timeout=1.0, grace_s=20.0,
                    **sizes)
    checks = _fencing_checks(c)
    checks["not_promoted_while_suspect"] = not any(
        e["event"] == "failover" for e in c["events"])
    return _cell("partition_fence", scheme, workload, seed, checks, c)


def partition_failover(scheme: str, workload: str, seed: int,
                       sizes: dict) -> dict:
    """Partition that OUTLASTS the grace window: the suspect node is
    declared failed, promoted away, and its stale acks are detected at
    failover instead of resync — the fenced ex-primary path."""
    t = sizes["num_ops"] // 3
    c = run_cluster(scheme, workload, nodes=4, replicas=2,
                    events=[("partition", t, "pm2"),
                            ("stale", t + 1, "pm2")],
                    seed=seed, heartbeat_timeout=1.0, grace_s=1.0,
                    **sizes)
    checks = _fencing_checks(c)
    checks["partition_promoted"] = any(
        e["event"] == "failover" and e["dead"] == "pm2"
        for e in c["events"])
    return _cell("partition_failover", scheme, workload, seed, checks, c)


def lag_reads(scheme: str, workload: str, seed: int, sizes: dict) -> dict:
    """Replica-lag reads: a healed-but-unsynced node looks reachable but
    holds a stale epoch; reads ranked to it MUST redirect to a serving
    replica (a lagging image never serves) until resync re-admits it."""
    q = sizes["num_ops"] // 4
    c = run_cluster(scheme, workload, nodes=4, replicas=2,
                    events=[("partition", q, "pm1"), ("heal", q + 1, "pm1"),
                            ("resync", 3 * q, "pm1")],
                    seed=seed, **sizes)
    return _cell("lag_reads", scheme, workload, seed, {
        "zero_committed_loss": c["committed_lost"] == 0,
        "lag_reads_redirected": c["chaos"]["lag_read_redirects"] > 0,
    }, c)


# -- delivery-fault family --------------------------------------------------
def delivery_faults(scheme: str, workload: str, seed: int,
                    sizes: dict) -> dict:
    """Lossy wire, no membership events: drops are timed out and
    retried with capped exponential backoff, duplicates absorbed,
    reorders re-synced — and the YCSB run stays lossless."""
    c = run_cluster(scheme, workload, nodes=4, replicas=2, seed=seed,
                    faults=_mild_faults(seed), retry=RetryPolicy(), **sizes)
    w = _wire_totals(c["stats"])
    # duplicates/reorders fire at 5% per round — a single small cell can
    # legitimately draw none, so THOSE paths gate at the grid level
    # (matrix totals), not per cell
    return _cell("delivery_faults", scheme, workload, seed, {
        "zero_committed_loss": c["committed_lost"] == 0,
        "drops_retried": w["retries"] > 0,
        "backoff_waited": w["backoff_us"] > 0,
        "no_spurious_give_ups": w["give_ups"] == 0,
    }, c)


# -- degradation drills -----------------------------------------------------
def read_only_degrade(scheme: str, workload: str, seed: int,
                      sizes: dict) -> dict:
    """Quorum loss: sequential kill+failover down to fewer serving nodes
    than the replication factor.  The cluster flips to read-only —
    every write is REJECTED (never acked under-replicated) while every
    previously acked key still reads back exactly."""
    rng = np.random.RandomState(seed)
    n = sizes["num_records"]
    cluster = ClusterStore(scheme, nodes=3, replicas=2,
                           node_slots=sizes["node_slots"])
    K = ycsb.make_key(np.arange(n))
    V = ycsb.make_value(rng, n)
    res = cluster.insert(K, V)
    acked = np.asarray(res.ok)
    for name in ("pm2", "pm1"):         # sequential: failover restores R
        cluster.kill(name)              # between kills where it still can
        cluster.failover(name)
    w = cluster.insert(ycsb.make_key(np.arange(n, n + 32)),
                       ycsb.make_value(rng, 32))
    rd = cluster.lookup(K[acked])
    good = np.asarray(rd.found) & (rd.values == V[acked]).all(axis=1)
    payload = {"committed_lost": int((~good).sum()),
               "chaos": dict(cluster.chaos), "stats": cluster.stats()}
    return _cell("read_only_degrade", scheme, workload, seed, {
        "went_read_only": cluster.read_only,
        "writes_rejected": (not w.ok.any()
                            and cluster.chaos["writes_rejected_read_only"]
                            > 0),
        "reads_still_serve": bool(good.all()),
    }, payload)


def timeout_giveup(scheme: str, workload: str, seed: int,
                   sizes: dict) -> dict:
    """Retry-budget exhaustion: a 100%-loss wire makes every delivery
    round drain its attempts and raise.  The cluster must surface that
    as UN-acked ops (the client saw no commit, so nothing is lost) —
    and recover to full service the moment the wire heals."""
    rng = np.random.RandomState(seed)
    n = sizes["num_records"]
    cluster = ClusterStore(scheme, nodes=3, replicas=2,
                           node_slots=sizes["node_slots"])
    K = ycsb.make_key(np.arange(n))
    V = ycsb.make_value(rng, n)
    acked = np.asarray(cluster.insert(K, V).ok)
    # the wire goes fully lossy AFTER the load: every endpoint now drops
    # every delivery, so each round exhausts its (shortened) budget
    for name in cluster.node_names():
        mem = cluster.node(name).mem
        mem.faults = FaultInjector(drop_p=1.0, seed=seed)
        mem.retry = RetryPolicy(max_attempts=3)
    W = ycsb.make_value(rng, 64)
    w = cluster.update(K[acked][:64], W)
    give_ups = _wire_totals(cluster.stats())["give_ups"]
    timeouts_seen = (cluster.chaos["write_timeouts"]
                     + cluster.chaos["read_timeouts"])
    cluster.quiesce_faults()            # the wire heals
    rd = cluster.lookup(K[acked])
    found = np.asarray(rd.found)
    # the 64 targeted keys are INDETERMINATE: the update applied on the
    # shard before its ack round died, so either value is legal — the
    # client was never told it committed.  Every untargeted key must
    # hold its exact acked value.
    old = (rd.values == V[acked]).all(axis=1)
    new = np.zeros_like(old)
    new[:64] = (rd.values[:64] == W).all(axis=1)
    targeted = np.zeros_like(old)
    targeted[:64] = True
    good = found & (old | (targeted & new))
    payload = {"committed_lost": int((~good).sum()),
               "chaos": dict(cluster.chaos), "stats": cluster.stats()}
    return _cell("timeout_giveup", scheme, workload, seed, {
        "no_acks_on_dead_wire": not w.ok.any(),
        "give_ups_raised": give_ups > 0,
        "timeouts_surfaced": timeouts_seen > 0,
        "lossless_after_heal": bool(good.all()),
        "untargeted_exact": bool((found & old)[~targeted].all()
                                 if (~targeted).any() else True),
    }, payload)


# -- soak -------------------------------------------------------------------
def soak(scheme: str, workload: str, seed: int, sizes: dict) -> dict:
    """Long churn run: join, partition + stale acks + heal + resync,
    second join, crash, graceful leave — back-to-back on a lossy wire.
    The union of every family's invariants must hold at the end.  The
    partition window closes (resync) BEFORE the crash: overlapping a
    partition of one replica with the death of its co-replica exceeds
    the <= R-1 concurrent-failure contract for that key."""
    sizes = dict(sizes, num_ops=2 * sizes["num_ops"])
    r = sizes["num_ops"] // 8
    c = run_cluster(scheme, workload, nodes=4, replicas=2,
                    events=[("join", r, "pmJ"),
                            ("partition", 2 * r, "pm1"),
                            ("stale", 2 * r + 1, "pm1"),
                            ("heal", 3 * r, "pm1"),
                            ("resync", 4 * r, "pm1"),
                            ("join", 5 * r, "pmK"),
                            ("kill", 6 * r, "pm0"),
                            ("leave", 7 * r, "pm3")],
                    seed=seed, faults=_mild_faults(seed),
                    retry=RetryPolicy(), heartbeat_timeout=2.0,
                    grace_s=5.0, **sizes)
    checks = _fencing_checks(c)
    checks["kill_promoted"] = any(e["event"] == "failover"
                                  for e in c["events"])
    checks["rebalance_within_bound"] = c["rebalance_within_bound"]
    checks["churn_membership_settled"] = c["nodes_final"] == 4
    return _cell("soak", scheme, workload, seed, checks, c)


SCENARIOS: Dict[str, Callable[..., dict]] = {
    "storm": storm,
    "storm_mid_join": storm_mid_join,
    "storm_mid_migration": storm_mid_migration,
    "partition_fence": partition_fence,
    "partition_failover": partition_failover,
    "lag_reads": lag_reads,
    "delivery_faults": delivery_faults,
    "read_only_degrade": read_only_degrade,
    "timeout_giveup": timeout_giveup,
    "soak": soak,
}


def run_scenario(name: str, *, scheme: str = "continuity",
                 workload: str = "A", seed: int = 0,
                 profile: str = "smoke") -> dict:
    """Run one named scenario cell; see `SCENARIOS` for the registry."""
    return SCENARIOS[name](scheme, workload, seed, dict(SIZES[profile]))

"""Render a per-phase latency/throughput table from an obs export.

    python -m repro.obs.report <base|export.trace.json> [--check] [--top N]

Reads the ``<base>.trace.json`` / ``<base>.metrics.json`` pair written by
`repro.obs.export.write_export` and prints:

  * the per-phase SPAN table — every span name with call count, total
    traced time, and p50/p99 span duration (durations aggregated through
    the same `Histogram` sketch the metrics use — the report has no
    second percentile implementation to disagree with);
  * the top-N spans by total time (the "Perfetto screenshot equivalent"
    EXPERIMENTS.md §Obs reproduces);
  * every metrics histogram with count/mean/p50/p90/p99/p999;
  * the headline ratio: when the export carries per-scheme ``e2e.op_us``
    histograms (a traced `cluster/sim.py --trace` run records the
    YCSB trio), the continuity-vs-pfarm and continuity-vs-level p50
    ratios per workload — the paper's ~1.7x latency ordering.

``--check`` is the `obs-smoke` CI gate: exit 1 unless the trace is
non-empty, the metrics payload is schema-valid, the e2e p50 ordering
matches the end-to-end band (full chain continuity <= level <= pfarm
on the write-mixed YCSB-A; continuity <= pfarm on read-only mixes,
where level's shorter probe chains undercut continuity's p50), and
the run recorded ZERO maintenance-SLO burns.
"""

from __future__ import annotations

import argparse
import re
import sys
from typing import Dict, List, Optional, Tuple

from repro.obs.export import load_export
from repro.obs.metrics import Histogram

_KEY_RE = re.compile(r"^(?P<name>[^{]+)(\{(?P<labels>.*)\})?$")


def parse_key(key: str) -> Tuple[str, Dict[str, str]]:
    """``"e2e.op_us{op=read,scheme=continuity}"`` -> (name, labels)."""
    m = _KEY_RE.match(key)
    assert m is not None, key
    labels = {}
    if m.group("labels"):
        for part in m.group("labels").split(","):
            k, _, v = part.partition("=")
            labels[k] = v
    return m.group("name"), labels


def span_table(trace: dict) -> List[dict]:
    """Aggregate complete-events by span name: count, total, p50/p99."""
    agg: Dict[str, Tuple[Histogram, int]] = {}
    for ev in trace.get("traceEvents", []):
        if ev.get("ph") != "X":
            continue
        h, _ = agg.setdefault(ev["name"], (Histogram(), 0))
        h.record(float(ev.get("dur", 0.0)))
    rows = []
    for name, (h, _) in agg.items():
        rows.append({"span": name, "count": h.count, "total_us": h.total,
                     "p50_us": h.percentile(50), "p99_us": h.percentile(99)})
    rows.sort(key=lambda r: -r["total_us"])
    return rows


def e2e_ratios(metrics: dict) -> Dict[str, Dict[str, float]]:
    """{workload: {scheme: merged p50}} from the e2e.op_us histograms."""
    per: Dict[str, Dict[str, Histogram]] = {}
    hists = metrics.get("metrics", {}).get("histograms", {})
    for key, hd in hists.items():
        name, labels = parse_key(key)
        if name != "e2e.op_us":
            continue
        wl, scheme = labels.get("workload", "?"), labels.get("scheme", "?")
        per.setdefault(wl, {}).setdefault(scheme, Histogram()) \
            .merge(Histogram.from_dict(hd))
    return {wl: {s: h.percentile(50) for s, h in by_s.items()}
            for wl, by_s in per.items()}


def slo_burns(metrics: dict) -> float:
    total = 0.0
    for key, v in metrics.get("metrics", {}).get("counters", {}).items():
        if parse_key(key)[0] == "maintenance.slo_burn":
            total += v
    return total


def _schema_errors(trace: Optional[dict],
                   metrics: Optional[dict]) -> List[str]:
    bad = []
    if trace is None:
        bad.append("trace artifact missing")
    elif not isinstance(trace.get("traceEvents"), list) \
            or not any(e.get("ph") == "X" for e in trace["traceEvents"]):
        bad.append("trace has no complete span events")
    if metrics is None:
        bad.append("metrics artifact missing")
    else:
        m = metrics.get("metrics")
        if not isinstance(m, dict) or \
                set(m) < {"counters", "gauges", "histograms"}:
            bad.append("metrics payload missing counters/gauges/histograms")
        elif not (m["counters"] or m["histograms"]):
            bad.append("metrics payload is empty")
        else:
            for key, hd in m["histograms"].items():
                if not isinstance(hd, dict) or "count" not in hd \
                        or "buckets" not in hd:
                    bad.append(f"histogram {key!r} malformed")
                    break
    return bad


def main(argv=None) -> int:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("path", help="export base path (or either artifact)")
    p.add_argument("--top", type=int, default=5,
                   help="spans in the top-by-total-time table")
    p.add_argument("--check", action="store_true",
                   help="CI gate: non-empty + schema-valid + e2e p50 "
                        "ordering + zero SLO burns")
    args = p.parse_args(argv)
    trace, metrics = load_export(args.path)
    bad = _schema_errors(trace, metrics)

    if trace is not None:
        rows = span_table(trace)
        print(f"{'span':34s} {'count':>7s} {'total_us':>12s} "
              f"{'p50_us':>10s} {'p99_us':>10s}")
        for r in rows:
            print(f"{r['span']:34s} {r['count']:7d} {r['total_us']:12.1f} "
                  f"{r['p50_us']:10.2f} {r['p99_us']:10.2f}")
        print(f"\ntop {args.top} spans by total traced time:")
        for r in rows[:args.top]:
            print(f"  {r['span']:32s} {r['total_us']:12.1f} us "
                  f"({r['count']} calls)")

    if metrics is not None:
        hists = metrics.get("metrics", {}).get("histograms", {})
        if hists:
            print(f"\n{'histogram':52s} {'count':>7s} {'p50':>9s} "
                  f"{'p90':>9s} {'p99':>9s} {'p999':>9s}")
            for key in sorted(hists):
                h = Histogram.from_dict(hists[key])
                print(f"{key:52s} {h.count:7d} {h.percentile(50):9.2f} "
                      f"{h.percentile(90):9.2f} {h.percentile(99):9.2f} "
                      f"{h.percentile(99.9):9.2f}")
        ratios = e2e_ratios(metrics)
        for wl in sorted(ratios):
            by_s = ratios[wl]
            if "continuity" not in by_s:
                continue
            base = by_s["continuity"]
            line = [f"e2e YCSB-{wl} p50: continuity {base:.2f}us"]
            for other in ("level", "pfarm"):
                if other in by_s and base > 0:
                    line.append(f"{other} {by_s[other]:.2f}us "
                                f"({by_s[other] / base:.2f}x)")
            print("\n" + ", ".join(line))
            # the CI ordering gate mirrors validate_bench's end-to-end
            # band: the FULL chain continuity <= level <= pfarm holds on
            # the write-mixed YCSB-A p50; on read-only mixes the repo's
            # own artifact has level probing under continuity's p50, so
            # there only the headline contrast continuity <= pfarm gates
            names = (("continuity", "level", "pfarm") if wl == "A"
                     else ("continuity", "pfarm"))
            chain = [by_s[s] for s in names if s in by_s]
            if any(a > b * (1 + 1e-9) for a, b in zip(chain, chain[1:])):
                bad.append(f"e2e p50 ordering violated on YCSB-{wl}: "
                           f"{by_s}")
        burns = slo_burns(metrics)
        print(f"\nmaintenance SLO burns: {burns:.0f}")
        if burns != 0:
            bad.append(f"{burns:.0f} maintenance steps burned their SLO "
                       f"(must be 0)")

    if args.check:
        for b in bad:
            print(f"FAIL: {b}", file=sys.stderr)
        return 1 if bad else 0
    return 0


if __name__ == "__main__":
    sys.exit(main())

"""Unified telemetry: metric sketches, span tracing, timeline export.

Everything the store/transport/cluster/cache stack emits flows through
this package (DESIGN.md §13):

  * `repro.obs.metrics` — counters, gauges, mergeable log-scale
    histogram sketches (p50/p90/p99/p999 within ~2.2% of exact);
  * `repro.obs.trace`   — nested spans with injectable clocks, causal
    parent/child links, and point events (doorbells, retries, fence
    waits, resize cohort moves, cache validate/fill, failover phases);
  * `repro.obs.export`  — Chrome-trace/Perfetto JSON + flat metrics
    JSON, byte-identical for same-seed runs;
  * `repro.obs.report`  — ``python -m repro.obs.report <base>`` renders
    the per-phase latency/throughput table and the CI ``--check`` gate.

Instrumented code imports the free functions::

    from repro import obs
    with obs.span("cluster.write", node=n):
        obs.event("rdma.doorbell", verbs=3)
        obs.get_registry().counter("rdma.posts").inc()

Both no-op (or hit the process-default registry) unless a tracer /
registry is installed — `obs.scope()` swaps in a fresh pair for traced
drills and restores on exit.
"""

from repro.obs.export import (METRICS_SUFFIX, TRACE_SUFFIX,
                              chrome_trace_events, export_payloads,
                              export_strings, load_export, write_export)
from repro.obs.metrics import (GROWTH, Counter, Gauge, Histogram,
                               MetricsRegistry, percentiles_from)
from repro.obs.trace import (Span, TickClock, Tracer, event, get_registry,
                             get_tracer, install, scope, set_registry, span)

__all__ = [
    "GROWTH", "Counter", "Gauge", "Histogram", "MetricsRegistry",
    "percentiles_from",
    "Span", "TickClock", "Tracer", "event", "get_registry", "get_tracer",
    "install", "scope", "set_registry", "span",
    "METRICS_SUFFIX", "TRACE_SUFFIX", "chrome_trace_events",
    "export_payloads", "export_strings", "load_export", "write_export",
]

"""Process-local metrics: counters, gauges, log-scale histogram sketches.

One `MetricsRegistry` per process (or per simulated endpoint/node — the
cluster merges node registries into one view), holding three sink kinds:

  * `Counter`  — monotonically increasing float/int total;
  * `Gauge`    — last-set value (plus the observed max, for SLO-style
    "worst step" reporting);
  * `Histogram`— fixed-bucket log-scale sketch with mergeable counts and
    percentile queries (p50/p90/p99/p999).

The histogram is the load-bearing piece: every latency claim in the
bench/obs artifacts (YCSB per-op-type latencies, fan-in queue tails,
cluster round latencies) is computed from these sketches, so bench
numbers and obs exports CANNOT disagree — they read the same buckets.

Bucketing: geometric buckets at ``GROWTH = 2**(1/32)`` per step (~2.2%
relative width) spanning [LO, LO * GROWTH**N).  A recorded value lands
in the unique bucket whose range contains it; `percentile()` linearly
interpolates between the geometric bucket midpoints holding the adjacent
order statistics (np.percentile's default method).  Hence the sketch's
exactness guarantee, property-tested in tests/test_obs.py:

    |sketch_pXX - exact_pXX| <= exact_pXX * (GROWTH - 1)

i.e. every percentile is within one bucket width (~2.2% relative) of the
sorted-list percentile, at O(1) memory independent of sample count, and
``merge()`` of two sketches is exactly the sketch of the concatenated
samples (bucket counts add).

jit discipline (DESIGN.md §13): these sinks are HOST-side state.  Hot
paths never call the registry from inside jitted code — they batch
device values and record at flush boundaries (a transport ``post()``, a
sim round, a maintenance step), exactly how `RemoteMemory` already stays
outside jit.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, Iterable, Optional, Tuple

import numpy as np

# log-scale bucket geometry: 32 buckets per octave over ~40 octaves
# (1e-3 .. ~1e9, microseconds in practice) — one int per touched bucket
GROWTH = 2.0 ** (1.0 / 32.0)
LO = 1e-3
N_BUCKETS = 1344            # 42 octaves: LO * 2**42 ~ 4.4e9
_LOG_GROWTH = math.log(GROWTH)
_PCTS = (50.0, 90.0, 99.0, 99.9)


class Counter:
    """Monotonic total.  ``inc`` accepts floats (e.g. microseconds)."""

    __slots__ = ("value",)

    def __init__(self, value: float = 0.0):
        self.value = value

    def inc(self, n: float = 1) -> None:
        self.value += n


class Gauge:
    """Last-set value + running max (the SLO "worst observed" lane)."""

    __slots__ = ("value", "max")

    def __init__(self):
        self.value = 0.0
        self.max = float("-inf")

    def set(self, v: float) -> None:
        self.value = float(v)
        if v > self.max:
            self.max = float(v)


class Histogram:
    """Fixed-bucket log-scale sketch; see the module docstring for the
    exactness bound.  Values <= 0 land in the underflow bucket (reported
    as 0.0 by percentile queries); values past the top land in overflow.
    """

    __slots__ = ("buckets", "underflow", "overflow", "count", "total",
                 "vmin", "vmax")

    def __init__(self):
        self.buckets: Dict[int, int] = {}   # sparse: bucket index -> count
        self.underflow = 0
        self.overflow = 0
        self.count = 0
        self.total = 0.0                    # exact sum (for the mean)
        self.vmin = float("inf")
        self.vmax = float("-inf")

    @staticmethod
    def bucket_of(v: float) -> int:
        return int(math.floor(math.log(v / LO) / _LOG_GROWTH))

    @staticmethod
    def bucket_mid(i: int) -> float:
        # geometric midpoint of [LO*G^i, LO*G^(i+1))
        return LO * GROWTH ** (i + 0.5)

    def record(self, v: float) -> None:
        v = float(v)
        self.count += 1
        self.total += v
        if v < self.vmin:
            self.vmin = v
        if v > self.vmax:
            self.vmax = v
        if v < LO:
            self.underflow += 1
            return
        i = self.bucket_of(v)
        if i >= N_BUCKETS:
            self.overflow += 1
            return
        self.buckets[i] = self.buckets.get(i, 0) + 1

    def record_many(self, values: Iterable[float]) -> None:
        a = np.asarray(list(values) if not isinstance(values, np.ndarray)
                       else values, np.float64).ravel()
        if a.size == 0:
            return
        self.count += int(a.size)
        self.total += float(a.sum())
        self.vmin = min(self.vmin, float(a.min()))
        self.vmax = max(self.vmax, float(a.max()))
        lo = a < LO
        self.underflow += int(lo.sum())
        a = a[~lo]
        if a.size == 0:
            return
        idx = np.floor(np.log(a / LO) / _LOG_GROWTH).astype(np.int64)
        hi = idx >= N_BUCKETS
        self.overflow += int(hi.sum())
        for i, c in zip(*np.unique(idx[~hi], return_counts=True)):
            self.buckets[int(i)] = self.buckets.get(int(i), 0) + int(c)

    def _order_stat(self, k: int) -> float:
        """0-indexed order statistic as a bucket midpoint: underflow
        first (reported 0.0), then the sparse buckets in index order,
        overflow last (reported as the exact max — best honest answer)."""
        if k < self.underflow:
            return 0.0
        seen = self.underflow
        for i in sorted(self.buckets):
            seen += self.buckets[i]
            if k < seen:
                return self.bucket_mid(i)
        return self.vmax

    def percentile(self, q: float) -> float:
        """Value at percentile ``q`` in [0, 100]; 0.0 on an empty sketch.

        Linear interpolation between adjacent order statistics at
        fractional ranks — `np.percentile`'s default method over the
        bucket midpoints, so a sketch percentile tracks the sorted-list
        one even when the rank lands exactly between two modes (e.g. a
        50/50 read/write mix whose p50 IS the boundary midpoint).  The
        error bound survives interpolation: a convex combination of two
        values each within relative error e of their true order stats is
        within e of the true interpolated value."""
        if self.count == 0:
            return 0.0
        pos = q / 100.0 * (self.count - 1)
        k = int(math.floor(pos))
        k = min(max(k, 0), self.count - 1)
        lo = self._order_stat(k)
        frac = pos - k
        if frac <= 0.0 or k + 1 > self.count - 1:
            return lo
        hi = self._order_stat(k + 1)
        return lo + frac * (hi - lo)

    @property
    def mean(self) -> float:
        return self.total / self.count if self.count else 0.0

    def merge(self, other: "Histogram") -> None:
        for i, c in other.buckets.items():
            self.buckets[i] = self.buckets.get(i, 0) + c
        self.underflow += other.underflow
        self.overflow += other.overflow
        self.count += other.count
        self.total += other.total
        self.vmin = min(self.vmin, other.vmin)
        self.vmax = max(self.vmax, other.vmax)

    def to_dict(self) -> dict:
        return {
            "count": self.count, "sum": self.total,
            "min": self.vmin if self.count else 0.0,
            "max": self.vmax if self.count else 0.0,
            "underflow": self.underflow, "overflow": self.overflow,
            "buckets": {str(i): c for i, c in sorted(self.buckets.items())},
            "percentiles": {f"p{f'{p:g}'.replace('.', '')}":
                            self.percentile(p) for p in _PCTS},
        }

    @classmethod
    def from_dict(cls, d: dict) -> "Histogram":
        h = cls()
        h.count = int(d["count"])
        h.total = float(d["sum"])
        h.underflow = int(d.get("underflow", 0))
        h.overflow = int(d.get("overflow", 0))
        h.buckets = {int(i): int(c) for i, c in d.get("buckets", {}).items()}
        if h.count:
            h.vmin = float(d.get("min", 0.0))
            h.vmax = float(d.get("max", 0.0))
        return h


@dataclasses.dataclass(frozen=True)
class _Key:
    name: str
    labels: Tuple[Tuple[str, str], ...]

    def __str__(self) -> str:
        if not self.labels:
            return self.name
        inner = ",".join(f"{k}={v}" for k, v in self.labels)
        return f"{self.name}{{{inner}}}"


def _key(name: str, labels: dict) -> _Key:
    return _Key(name, tuple(sorted((k, str(v)) for k, v in labels.items())))


class MetricsRegistry:
    """Label-keyed sink table.  ``counter/gauge/histogram`` get-or-create
    the sink for (name, labels); `merge` folds another registry in
    (counters add, histograms merge, gauges keep the max — the merged
    view answers "worst anywhere", the per-node registries keep the
    per-node answer)."""

    def __init__(self):
        self.counters: Dict[_Key, Counter] = {}
        self.gauges: Dict[_Key, Gauge] = {}
        self.histograms: Dict[_Key, Histogram] = {}

    def counter(self, name: str, **labels) -> Counter:
        k = _key(name, labels)
        c = self.counters.get(k)
        if c is None:
            c = self.counters[k] = Counter()
        return c

    def gauge(self, name: str, **labels) -> Gauge:
        k = _key(name, labels)
        g = self.gauges.get(k)
        if g is None:
            g = self.gauges[k] = Gauge()
        return g

    def histogram(self, name: str, **labels) -> Histogram:
        k = _key(name, labels)
        h = self.histograms.get(k)
        if h is None:
            h = self.histograms[k] = Histogram()
        return h

    def value(self, name: str, default: float = 0.0, **labels) -> float:
        """Read a counter without creating it (stats()-view helper)."""
        c = self.counters.get(_key(name, labels))
        return c.value if c is not None else default

    def find_histograms(self, name: str) -> Dict[str, Histogram]:
        """{label-string: hist} for every histogram with this name."""
        return {str(k): h for k, h in self.histograms.items()
                if k.name == name}

    def merge(self, other: "MetricsRegistry") -> "MetricsRegistry":
        for k, c in other.counters.items():
            self.counters.setdefault(k, Counter()).inc(c.value)
        for k, g in other.gauges.items():
            mine = self.gauges.setdefault(k, Gauge())
            mine.set(max(g.value, mine.max if mine.max != float("-inf")
                         else g.value, g.max))
        for k, h in other.histograms.items():
            self.histograms.setdefault(k, Histogram()).merge(h)
        return self

    def to_dict(self) -> dict:
        """The flat metrics-JSON export (`repro.obs.export`)."""
        out = {"counters": {}, "gauges": {}, "histograms": {}}
        for k, c in self.counters.items():
            out["counters"][str(k)] = c.value
        for k, g in self.gauges.items():
            out["gauges"][str(k)] = {"value": g.value, "max": g.max}
        for k, h in self.histograms.items():
            out["histograms"][str(k)] = h.to_dict()
        return out

    def is_empty(self) -> bool:
        return not (self.counters or self.gauges or self.histograms)


def percentiles_from(hist: Optional[Histogram],
                     pcts=(50.0, 99.0)) -> Dict[str, float]:
    """{"p50_us": ..., "p99_us": ...} — the one shape every bench section
    reports latency in, always computed from a sketch."""
    return {f"p{f'{p:g}'.replace('.', '')}_us":
            (hist.percentile(p) if hist is not None else 0.0) for p in pcts}

"""Nested span tracing with injectable clocks and causal links.

A `Tracer` records a tree of spans (``with span("cluster.write",
node=n):``) plus point-in-time span EVENTS (doorbell rings, retries,
fence waits, resize cohort moves, cache validate/fill, epoch bumps,
failover phases).  Parent/child causality is explicit: every span
carries its parent's id, taken from the tracer's span stack at entry.

Clock injection is the determinism contract, mirroring how
`runtime.fault.HeartbeatMonitor` takes an injectable clock: the default
`TickClock` advances a fixed amount per call, so a traced simulation's
export is a pure function of its call sequence — two same-seed runs
produce byte-identical trace JSON (a tier-1 test and the `obs-smoke` CI
gate).  Pass ``clock=time.perf_counter``-style callables (returning
SECONDS; the tracer scales to us) to trace real wall time instead —
wall-clock traces are for humans in Perfetto, never for CI comparison.

Instrumented code does NOT hold a tracer: it calls the module-level
`span()`/`event()` free functions, which no-op (one attribute load) when
no tracer is installed — the instrumentation sweep costs nothing in
untraced runs.  Install with `install(tracer)` or the `scope()` context
manager (which also swaps in a fresh metrics registry and restores both
on exit — what tests and the CI drills use).
"""

from __future__ import annotations

import contextlib
from typing import Any, Dict, List, Optional

from repro.obs.metrics import MetricsRegistry


class TickClock:
    """Deterministic clock: returns ``n * tick_us`` on the n-th call.

    Time is a call counter, not wall time — a span's "duration" counts
    the traced operations that happened inside it, which is exactly the
    reproducible quantity a simulated cluster has (its real latencies
    live in the metrics histograms, priced by the `LinkModel`)."""

    def __init__(self, tick_us: float = 1.0):
        self.tick_us = tick_us
        self.n = 0

    def __call__(self) -> float:
        self.n += 1
        return self.n * self.tick_us


class Span:
    __slots__ = ("span_id", "parent_id", "name", "attrs", "t0_us", "t1_us",
                 "events")

    def __init__(self, span_id: int, parent_id: Optional[int], name: str,
                 attrs: Dict[str, Any], t0_us: float):
        self.span_id = span_id
        self.parent_id = parent_id
        self.name = name
        self.attrs = attrs
        self.t0_us = t0_us
        self.t1_us = t0_us
        self.events: List[dict] = []

    @property
    def dur_us(self) -> float:
        return self.t1_us - self.t0_us


class Tracer:
    """Span recorder.  ``clock`` returns MICROSECONDS when it is a
    `TickClock` (or any callable flagged ``.returns_us = True``), else
    seconds (perf_counter-style) scaled by 1e6."""

    def __init__(self, clock=None):
        self.clock = clock if clock is not None else TickClock()
        self._scale = 1.0 if isinstance(self.clock, TickClock) \
            or getattr(self.clock, "returns_us", False) else 1e6
        self.spans: List[Span] = []          # finished, in completion order
        self.stack: List[Span] = []
        self._next_id = 1
        self.dropped_events = 0              # events with no open span

    def _now(self) -> float:
        return float(self.clock()) * self._scale

    @contextlib.contextmanager
    def span(self, name: str, **attrs):
        s = Span(self._next_id,
                 self.stack[-1].span_id if self.stack else None,
                 name, attrs, self._now())
        self._next_id += 1
        self.stack.append(s)
        try:
            yield s
        finally:
            s.t1_us = self._now()
            self.stack.pop()
            self.spans.append(s)

    def event(self, name: str, **attrs) -> None:
        """Point event attached to the innermost open span.  An event
        with no open span is counted and dropped (never an error: the
        transport fires events from whatever context called it)."""
        if not self.stack:
            self.dropped_events += 1
            return
        self.stack[-1].events.append(
            {"name": name, "ts_us": self._now(), "attrs": attrs})


class _NullSpan:
    """The no-tracer fast path: a reusable no-op context manager."""

    def __enter__(self):
        return None

    def __exit__(self, *exc):
        return False


_NULL_SPAN = _NullSpan()
_TRACER: Optional[Tracer] = None
_REGISTRY = MetricsRegistry()        # the process-local default registry


def install(tracer: Optional[Tracer]) -> None:
    global _TRACER
    _TRACER = tracer


def get_tracer() -> Optional[Tracer]:
    return _TRACER


def get_registry() -> MetricsRegistry:
    return _REGISTRY


def set_registry(reg: MetricsRegistry) -> MetricsRegistry:
    global _REGISTRY
    old = _REGISTRY
    _REGISTRY = reg
    return old


def span(name: str, **attrs):
    """``with obs.span("cluster.write", op="insert"):`` — no-op (shared
    null context) unless a tracer is installed."""
    t = _TRACER
    if t is None:
        return _NULL_SPAN
    return t.span(name, **attrs)


def event(name: str, **attrs) -> None:
    t = _TRACER
    if t is not None:
        t.event(name, **attrs)


@contextlib.contextmanager
def scope(tracer: Optional[Tracer] = None,
          registry: Optional[MetricsRegistry] = None):
    """Install a (tracer, fresh registry) pair for the duration; restores
    the previous pair on exit.  Yields ``(tracer, registry)`` — the CI
    drills run inside one scope and export exactly what it captured."""
    global _TRACER
    tracer = tracer if tracer is not None else Tracer()
    registry = registry if registry is not None else MetricsRegistry()
    prev_t, prev_r = _TRACER, set_registry(registry)
    _TRACER = tracer
    try:
        yield tracer, registry
    finally:
        _TRACER = prev_t
        set_registry(prev_r)

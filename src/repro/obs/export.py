"""Trace/metrics export: Chrome-trace (Perfetto-loadable) + flat metrics.

Two artifacts per traced run, written side by side:

  * ``<base>.trace.json``   — Chrome trace event format (the ``X``
    complete-event flavour plus ``i`` instants for span events and ``M``
    metadata rows naming tracks), loadable directly in Perfetto /
    chrome://tracing.  Track (tid) assignment: spans carrying a ``node``
    attr get that node's track, everything else rides track 0 — so a
    cluster run renders one lane per PM node.
  * ``<base>.metrics.json`` — `MetricsRegistry.to_dict()` (counters,
    gauges, histogram sketches with their percentiles) plus the caller's
    ``meta`` block.

Both files are dumped with ``sort_keys`` and no wall-clock timestamps,
so a deterministic run (seeded streams + `TickClock`) exports
byte-identically — the property `tests/test_obs.py` and the `obs-smoke`
CI job gate.

`python -m repro.obs.report <base>` renders the per-phase latency table
from these files (see `repro.obs.report`).
"""

from __future__ import annotations

import json
from typing import Dict, List, Optional, Tuple

from repro.obs.metrics import MetricsRegistry
from repro.obs.trace import Tracer

TRACE_SUFFIX = ".trace.json"
METRICS_SUFFIX = ".metrics.json"


def chrome_trace_events(tracer: Tracer) -> List[dict]:
    """The tracer's spans + events as Chrome trace events."""
    tracks: Dict[str, int] = {}

    def tid_of(span) -> int:
        node = span.attrs.get("node")
        if node is None:
            return 0
        name = str(node)
        if name not in tracks:
            tracks[name] = len(tracks) + 1
        return tracks[name]

    events: List[dict] = []
    for s in tracer.spans:
        tid = tid_of(s)
        args = {k: v for k, v in sorted(s.attrs.items())}
        if s.parent_id is not None:
            args["parent_span"] = s.parent_id
        args["span_id"] = s.span_id
        events.append({
            "name": s.name, "cat": s.name.split(".", 1)[0], "ph": "X",
            "ts": s.t0_us, "dur": s.dur_us, "pid": 0, "tid": tid,
            "args": args,
        })
        for ev in s.events:
            events.append({
                "name": ev["name"], "cat": ev["name"].split(".", 1)[0],
                "ph": "i", "ts": ev["ts_us"], "pid": 0, "tid": tid,
                "s": "t",
                "args": dict(sorted(ev["attrs"].items()),
                             span_id=s.span_id),
            })
    # stable render order: by timestamp then span id (completion order of
    # nested spans is child-first; Perfetto sorts by ts anyway, and a
    # deterministic file needs a deterministic order)
    events.sort(key=lambda e: (e["ts"], e["args"].get("span_id", 0),
                               e["ph"]))
    meta = [{"name": "thread_name", "ph": "M", "pid": 0, "tid": 0,
             "args": {"name": "main"}}]
    for name, tid in sorted(tracks.items(), key=lambda kv: kv[1]):
        meta.append({"name": "thread_name", "ph": "M", "pid": 0,
                     "tid": tid, "args": {"name": name}})
    return meta + events


def export_payloads(tracer: Optional[Tracer],
                    registry: Optional[MetricsRegistry],
                    meta: Optional[dict] = None) -> Tuple[dict, dict]:
    """(trace_payload, metrics_payload) — the two artifact bodies."""
    trace = {
        "traceEvents": chrome_trace_events(tracer) if tracer else [],
        "displayTimeUnit": "ns",
        "otherData": dict(meta or {}),
    }
    metrics = {
        "meta": dict(meta or {}),
        "metrics": registry.to_dict() if registry else
        {"counters": {}, "gauges": {}, "histograms": {}},
    }
    return trace, metrics


def write_export(base: str, tracer: Optional[Tracer],
                 registry: Optional[MetricsRegistry],
                 meta: Optional[dict] = None) -> Tuple[str, str]:
    """Write ``<base>.trace.json`` + ``<base>.metrics.json``; returns the
    two paths.  ``base`` may already carry either suffix."""
    for suf in (TRACE_SUFFIX, METRICS_SUFFIX):
        if base.endswith(suf):
            base = base[: -len(suf)]
    trace, metrics = export_payloads(tracer, registry, meta)
    tpath, mpath = base + TRACE_SUFFIX, base + METRICS_SUFFIX
    with open(tpath, "w") as f:
        json.dump(trace, f, indent=1, sort_keys=True)
    with open(mpath, "w") as f:
        json.dump(metrics, f, indent=1, sort_keys=True)
    return tpath, mpath


def export_strings(tracer: Optional[Tracer],
                   registry: Optional[MetricsRegistry],
                   meta: Optional[dict] = None) -> Tuple[str, str]:
    """The two artifact bodies as canonical JSON strings (the unit the
    byte-identity tests compare)."""
    trace, metrics = export_payloads(tracer, registry, meta)
    return (json.dumps(trace, indent=1, sort_keys=True),
            json.dumps(metrics, indent=1, sort_keys=True))


def load_export(path: str) -> Tuple[Optional[dict], Optional[dict]]:
    """Load (trace, metrics) given a base path or either artifact path;
    a missing sibling loads as None."""
    base = path
    for suf in (TRACE_SUFFIX, METRICS_SUFFIX):
        if base.endswith(suf):
            base = base[: -len(suf)]
    out = []
    for suf in (TRACE_SUFFIX, METRICS_SUFFIX):
        try:
            with open(base + suf) as f:
                out.append(json.load(f))
        except FileNotFoundError:
            out.append(None)
    return out[0], out[1]

"""AdamW with ZeRO-1-style optimizer-state sharding (no optax available).

Master params are f32 (models cast to bf16 at use sites); m/v moments are f32.
With ``zero1=True`` the moments are additionally sharded over the DATA axis on
the largest divisible dim of each leaf — GSPMD inserts the reduce-scatter /
all-gather pair around the elementwise update, which is exactly the ZeRO-1
communication pattern.
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

F32 = jnp.float32


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup: int = 100
    decay_steps: int = 10000
    zero1: bool = True
    grad_dtype: str = "float32"   # bfloat16 => compressed DP all-reduce


class OptState(NamedTuple):
    m: dict
    v: dict
    step: jnp.ndarray


def init(params) -> OptState:
    z = lambda p: jnp.zeros(p.shape, F32)
    return OptState(m=jax.tree.map(z, params), v=jax.tree.map(z, params),
                    step=jnp.zeros((), jnp.int32))


def schedule(cfg: OptConfig, step):
    warm = jnp.minimum(step / max(cfg.warmup, 1), 1.0)
    t = jnp.clip((step - cfg.warmup) / max(cfg.decay_steps - cfg.warmup, 1),
                 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * t))
    return cfg.lr * warm * (0.1 + 0.9 * cos)


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(F32)))
                        for x in jax.tree.leaves(tree)))


def apply_updates(cfg: OptConfig, params, grads, state: OptState):
    """One AdamW step; returns (params, state, stats)."""
    step = state.step + 1
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gn + 1e-9))
    lr = schedule(cfg, step)
    c1 = 1 - cfg.b1 ** step.astype(F32)
    c2 = 1 - cfg.b2 ** step.astype(F32)

    def upd(p, g, m, v):
        g = g.astype(F32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        mh = m / c1
        vh = v / c2
        step_dir = mh / (jnp.sqrt(vh) + cfg.eps)
        wd = cfg.weight_decay if p.ndim >= 2 else 0.0
        newp = p.astype(F32) - lr * (step_dir + wd * p.astype(F32))
        return newp.astype(p.dtype), m, v

    out = jax.tree.map(upd, params, grads, state.m, state.v)
    leaves, treedef = jax.tree.flatten(out, is_leaf=lambda x: isinstance(x, tuple))
    newp = treedef.unflatten([l[0] for l in leaves])
    newm = treedef.unflatten([l[1] for l in leaves])
    newv = treedef.unflatten([l[2] for l in leaves])
    return newp, OptState(newm, newv, step), {"grad_norm": gn, "lr": lr}


def opt_logical_axes(param_axes: dict, params, data_extent: int,
                     zero1: bool) -> dict:
    """Logical axes for m/v: param axes + ZeRO-1 sharding over the data axis
    on the largest divisible dim whose logical name maps to NO mesh axis
    (i.e. a dim the TP rules leave replicated)."""
    from repro.distribution.sharding import get_rules
    rules = get_rules()

    def leaf(ax, p):
        ax = tuple(ax) if ax else (None,) * p.ndim
        if not zero1:
            return ax
        best, best_dim = -1, -1
        for i, (name, dim) in enumerate(zip(ax, p.shape)):
            free = name is None or not rules.get(name)
            if free and dim % data_extent == 0 and dim > best:
                best, best_dim = dim, i
        if best_dim < 0:
            return ax
        return tuple("zero" if i == best_dim else n for i, n in enumerate(ax))
    return jax.tree.map(leaf, param_axes, params,
                        is_leaf=lambda x: isinstance(x, tuple) or x is None)

"""Train step: value_and_grad + microbatch accumulation + AdamW.

Distributed behaviour falls out of GSPMD: the batch is sharded over
(pod, data), parameters over model (+ZeRO'd moments over data), so autodiff's
mean-loss gradient produces the DP all-reduce, and ``grad_dtype="bfloat16"``
halves that all-reduce's payload (gradient compression; the moments stay f32
so the update is exact up to the cast).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.training import optimizer as O

F32 = jnp.float32


def microbatch_grads(cfg: ModelConfig, params, batch, num_micro: int,
                     grad_dtype):
    """Gradient accumulation over ``num_micro`` microbatches via lax.scan."""
    def lossf(p, mb):
        return T.loss_fn(cfg, p, mb)

    if num_micro <= 1:
        loss, grads = jax.value_and_grad(lossf)(params, batch)
        return loss, jax.tree.map(lambda g: g.astype(grad_dtype), grads)

    def split(x):
        return x.reshape(num_micro, x.shape[0] // num_micro, *x.shape[1:])

    mbs = jax.tree.map(split, batch)

    def step(carry, mb):
        acc, ls = carry
        loss, grads = jax.value_and_grad(lossf)(params, mb)
        acc = jax.tree.map(lambda a, g: a + g.astype(grad_dtype), acc, grads)
        return (acc, ls + loss), None

    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, grad_dtype), params)
    (acc, ls), _ = jax.lax.scan(step, (zeros, jnp.zeros((), F32)), mbs)
    inv = 1.0 / num_micro
    return ls * inv, jax.tree.map(lambda g: g * inv, acc)


def make_train_step(cfg: ModelConfig, opt_cfg: O.OptConfig,
                    num_micro: int = 1):
    grad_dtype = jnp.dtype(opt_cfg.grad_dtype)

    def train_step(params, opt_state, batch):
        loss, grads = microbatch_grads(cfg, params, batch, num_micro,
                                       grad_dtype)
        params, opt_state, stats = O.apply_updates(opt_cfg, params, grads,
                                                   opt_state)
        stats["loss"] = loss
        return params, opt_state, stats

    return train_step

"""Training substrate: optimizer (AdamW + ZeRO-1), train step, schedules."""
